"""Jit'd public wrappers around the Pallas kernels.

Layout adapters: models use (B, S, H, D) / (B, S, KV, D); the kernels use
(N=B*KV, G, S, D) with GQA folded. ``interpret`` defaults to True (CPU
container); on real TPU pass interpret=False (or set REPRO_PALLAS_COMPILE=1).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_attention import flash_block_ragged, flash_causal
from repro.kernels.decode_attention import DEFAULT_TK as DEFAULT_DECODE_TK
from repro.kernels.decode_attention import flash_decode
from repro.kernels.rope_shift import rope_shift, rope_shift_tokens

INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _pad_seq(x, target: int, axis: int = 1):
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fold(q, k, v):
    """(B,Sq,H,D)x(B,Skv,KV,D) -> q (B*KV, G, Sq, D); k/v (B*KV, Skv, D)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4).reshape(
        B * KV, G, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    return qf, kf, vf


def _unfold(o, B, H, D):
    """(B*KV, G, S, D) -> (B, S, H, D)."""
    N, G, S, _ = o.shape
    KV = N // B
    return o.reshape(B, KV, G, S, D).transpose(0, 3, 1, 2, 4).reshape(
        B, S, H, D)


def block_attention_prefill(q, k, v, num_blocks: int = 0, scale: float = None,
                            softcap: float = 0.0,
                            interpret: bool = INTERPRET,
                            block_lens=None, layout=None):
    """Block-attention prefill (paper Fig. 1).

    The block map comes from ``num_blocks`` (uniform split; any remainder
    joins the final block — no ``S % num_blocks == 0`` restriction),
    ``block_lens`` (a (nb,) or PER-ROW (B, nb) int array / nested sequence
    of block lengths, each row summing to S — ragged RAG passages, ragged
    training batches), or a ``core.blocks.BlockLayout`` (``layout=``, the
    unified structure object — its ``starts`` drive the same per-row
    kernel). Two dispatch strategies:

    * uniform & divisible — blocks folded into the batch dim (the grid
      never visits a cross-block tile) + one global final-block pass:
      exact block-granular sparsity, FLOPs Σ block_len² + L_final·S;
    * ragged / non-divisible / per-row — ONE ``flash_block_ragged``
      launch: the (B, nb+1) cumulative boundaries are scalar-prefetched
      into SMEM and drive per-row per-tile liveness plus the exact
      per-row mask. Tile sizes adapt to the smallest host-known block
      length (floor 64) so grid sparsity stays close to block-granular;
      blocks smaller than a tile still pay masked-MAC waste within their
      tile (tile-granular, not row-granular, sparsity — DESIGN.md §1).
    """
    if scale is None:   # keyword-form callers must not silently get 1.0
        raise TypeError("block_attention_prefill: scale is required")
    sel_keep = None
    if layout is not None:
        assert block_lens is None and num_blocks == 0, \
            "pass exactly one of layout / block_lens / num_blocks"
        assert layout.starts is not None, "layout has no boundary array"
        lens = layout.row_starts()
        lens = lens[..., 1:] - lens[..., :-1]
        if layout.starts.ndim == 1:
            lens = lens[0]
        block_lens = (np.asarray(lens) if not isinstance(lens, jax.Array)
                      else lens)
        sel = getattr(layout, "selected", None)
        if sel is not None:
            # §10 selection: always take the ragged kernel — it carries the
            # per-row keep operand (the uniform fold has no final-pass rows
            # to select against)
            sel_keep = jnp.asarray(sel, jnp.int32)
            if sel_keep.ndim == 1:
                sel_keep = sel_keep[None]
            if isinstance(block_lens, jax.Array):
                tile = 256                # traced lens: no host info to adapt
            else:
                lens_arr = np.asarray(block_lens)
                tile = min(256, max(64, _next_pow2(
                    int(lens_arr[lens_arr > 0].min()))))
            return _block_attention_ragged(
                q, k, v, jnp.asarray(block_lens, jnp.int32), scale, softcap,
                interpret, tile, sel_keep=sel_keep)
    if block_lens is not None and not isinstance(block_lens, jax.Array):
        # host-side lens: catch a bad block map here, before tracing would
        # silently mask the tail (device-array lens are the caller's
        # contract — a sum check there would force a sync)
        lens = np.asarray(block_lens, np.int64)
        if lens.ndim == 1 and lens.sum() != q.shape[1]:
            raise ValueError(
                f"block_lens sum {lens.sum()} != seq len {q.shape[1]}")
        if lens.ndim == 2:
            if lens.shape[0] != q.shape[0]:
                raise ValueError(
                    f"per-row block_lens rows {lens.shape[0]} != "
                    f"batch {q.shape[0]}")
            if (lens.sum(axis=1) != q.shape[1]).any():
                raise ValueError(
                    f"per-row block_lens sums {lens.sum(axis=1).tolist()} "
                    f"!= seq len {q.shape[1]}")
            if (lens == lens[0]).all():   # every row shares one layout
                lens = lens[0]
        if lens.ndim == 1 and len(set(lens.tolist())) == 1:  # uniform
            return _block_attention_uniform(q, k, v, lens.shape[0], scale,
                                            softcap, interpret)
        tile = min(256, max(64, _next_pow2(int(lens[lens > 0].min()))))
        return _block_attention_ragged(q, k, v,
                                       jnp.asarray(lens, jnp.int32),
                                       scale, softcap, interpret, tile)
    if block_lens is None:
        assert num_blocks > 0, "need num_blocks, block_lens or layout"
        S = q.shape[1]
        if S % num_blocks == 0:
            return _block_attention_uniform(q, k, v, num_blocks, scale,
                                            softcap, interpret)
        L = S // num_blocks
        lens = [L] * (num_blocks - 1) + [S - L * (num_blocks - 1)]
        block_lens = jnp.asarray(lens, jnp.int32)
        tile = min(256, max(64, _next_pow2(L)))
    else:
        tile = 256                        # traced lens: no host info to adapt
    return _block_attention_ragged(q, k, v, block_lens, scale, softcap,
                                   interpret, tile)


@functools.partial(jax.jit, static_argnames=(
    "num_blocks", "scale", "softcap", "interpret"))
def _block_attention_uniform(q, k, v, num_blocks, scale, softcap, interpret):
    """Uniform-split fast path: blocks folded into batch (grid never visits
    a cross-block tile) + one global final-block pass."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    L = S // num_blocks

    qb = q.reshape(B * num_blocks, L, H, D)
    kb = k.reshape(B * num_blocks, L, KV, D)
    vb = v.reshape(B * num_blocks, L, KV, D)
    qf, kf, vf = _fold(qb, kb, vb)
    o_within = flash_causal(qf, kf, vf, scale=scale, tq=min(256, L),
                            tk=min(512, L), softcap=softcap,
                            interpret=interpret)
    o_within = _unfold(o_within, B * num_blocks, H, D).reshape(B, S, H, D)
    if num_blocks == 1:
        return o_within

    qf2, kf2, vf2 = _fold(q[:, S - L:], k, v)
    o_final = flash_causal(qf2, kf2, vf2, scale=scale, q_offset=S - L,
                           tq=min(256, L), tk=min(512, S), softcap=softcap,
                           interpret=interpret)
    o_final = _unfold(o_final, B, H, D)
    return jnp.concatenate([o_within[:, : S - L], o_final], axis=1)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "interpret", "tile"))
def _block_attention_ragged(q, k, v, block_lens, scale, softcap, interpret,
                            tile, sel_keep=None):
    """One-launch ragged dispatch; ``block_lens`` (nb,) shared or (B, nb)
    per-row — the kernel's batched boundary operand either way. Optional
    ``sel_keep`` (B, nb) threads the §10 final-pass block selection."""
    B, S, H, D = q.shape
    block_lens = jnp.asarray(block_lens, jnp.int32)
    zeros = jnp.zeros(block_lens.shape[:-1] + (1,), jnp.int32)
    starts = jnp.concatenate(
        [zeros, jnp.cumsum(block_lens, axis=-1, dtype=jnp.int32)], axis=-1)
    if sel_keep is not None:
        nb = starts.shape[-1] - 1
        # the kernel maps grid row -> boundary row via starts' batch dim, so
        # a shared layout with per-row selection must broadcast both to B
        starts = jnp.broadcast_to(starts.reshape(-1, nb + 1), (B, nb + 1))
        sel_keep = jnp.broadcast_to(
            jnp.asarray(sel_keep, jnp.int32).reshape(-1, nb), (B, nb))

    tq = min(tile, _next_pow2(S))
    tk = min(max(tile, 512) if tile >= 256 else tile, _next_pow2(S))
    qp = _pad_seq(q, -(-S // tq) * tq)
    kp = _pad_seq(k, -(-S // tk) * tk)
    vp = _pad_seq(v, -(-S // tk) * tk)
    qf, kf, vf = _fold(qp, kp, vp)
    o = flash_block_ragged(qf, kf, vf, starts, scale=scale, tq=tq, tk=tk,
                           softcap=softcap, interpret=interpret,
                           sel_keep=sel_keep)
    return _unfold(o, B, H, D)[:, :S]


@functools.partial(jax.jit, static_argnames=(
    "scale", "q_offset", "softcap", "interpret"))
def causal_attention(q, k, v, scale: float, q_offset: int = 0,
                     softcap: float = 0.0, interpret: bool = INTERPRET):
    """Plain causal flash attention (full-attention mode)."""
    B, S, H, D = q.shape
    qf, kf, vf = _fold(q, k, v)
    o = flash_causal(qf, kf, vf, scale=scale, q_offset=q_offset,
                     tq=min(256, S), tk=min(512, k.shape[1]),
                     softcap=softcap, interpret=interpret)
    return _unfold(o, B, H, D)


@functools.partial(jax.jit, static_argnames=(
    "scale", "window", "softcap", "interpret"))
def decode_attention(q, k_cache, v_cache, cache_len, scale: float,
                     window: int = 0, softcap: float = 0.0,
                     interpret: bool = INTERPRET,
                     sel_starts=None, sel_keep=None):
    """Single-token decode. q (B,1,H,D); cache_len int32 incl. the new token —
    a scalar (shared length) or a (B,) per-row vector (paged ragged batch).

    ``sel_starts`` (B, NBS+1) / ``sel_keep`` (B, NBS) thread the §10 block
    selection into the kernel (per-row operands repeat across KV heads)."""
    B, _, H, D = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, Skv, D)
    tk = min(DEFAULT_DECODE_TK, Skv)
    pad = (-Skv) % tk
    if pad:   # odd max_seq: pad the cache view to a tile multiple — the
        kf = _pad_seq(kf, Skv + pad)      # padded tail sits past every row's
        vf = _pad_seq(vf, Skv + pad)      # cache_len, so it is masked dead
    # per-row length vector: row b's KV-head rows all mask at cache_len[b]
    cl = jnp.broadcast_to(
        jnp.reshape(jnp.asarray(cache_len, jnp.int32), (-1,)), (B,))
    cl = jnp.repeat(cl, KV)                                  # (B*KV,)
    if sel_starts is not None:
        sel_starts = jnp.repeat(jnp.asarray(sel_starts, jnp.int32), KV,
                                axis=0)
        sel_keep = jnp.repeat(jnp.asarray(sel_keep, jnp.int32), KV, axis=0)
    o = flash_decode(qf, kf, vf, cl, scale=scale, window=window, tk=tk,
                     softcap=softcap, interpret=interpret,
                     sel_starts=sel_starts, sel_keep=sel_keep)
    return o.reshape(B, KV, G, D).reshape(B, 1, H, D)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "interpret"))
def paged_decode_attention(q, pool_k, pool_v, tables, page_starts, cache_len,
                           scale: float, softcap: float = 0.0,
                           interpret: bool = INTERPRET, keep=None):
    """Single-token decode through the shared paged pool.

    q (B,1,H,D); pool_k/v (num_pages, PS, KV, D) — the SHARED slabs, not
    per-row caches; tables (B, MP) int32 page ids; page_starts (B, MP+1)
    int32 cumulative page occupancy; cache_len as in ``decode_attention``.
    GQA folds the head axis into both the pool (page p of head h becomes
    folded page ``p*KV + h``) and the tables, so the kernel still sees
    plain (P', PS, D) slabs and a per-row (N, MP) table.
    """
    B, _, H, D = q.shape
    PS, KV = pool_k.shape[1], pool_k.shape[2]
    G = H // KV
    MP = tables.shape[1]
    qf = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kf = pool_k.transpose(0, 2, 1, 3).reshape(-1, PS, D)     # (P*KV, PS, D)
    vf = pool_v.transpose(0, 2, 1, 3).reshape(-1, PS, D)
    heads = jnp.arange(KV, dtype=jnp.int32)[None, :, None]
    tbl = (jnp.asarray(tables, jnp.int32)[:, None, :] * KV
           + heads).reshape(B * KV, MP)
    starts = jnp.repeat(jnp.asarray(page_starts, jnp.int32), KV, axis=0)
    cl = jnp.broadcast_to(
        jnp.reshape(jnp.asarray(cache_len, jnp.int32), (-1,)), (B,))
    cl = jnp.repeat(cl, KV)                                  # (B*KV,)
    if keep is not None:   # §10 selection over table slots, folded per head
        keep = jnp.repeat(jnp.asarray(keep, jnp.int32), KV, axis=0)
    o = flash_decode(qf, kf, vf, cl, scale=scale, softcap=softcap,
                     interpret=interpret, block_tables=tbl,
                     page_starts=starts, keep=keep)
    return o.reshape(B, KV, G, D).reshape(B, 1, H, D)


@functools.partial(jax.jit, static_argnames=(
    "rotary_dim", "theta", "interleaved", "interpret"))
def reencode_block_kv(k, delta, rotary_dim: int, theta: float,
                      interleaved: bool = False, interpret: bool = INTERPRET):
    """Fused Eq.-3 re-rotation of cached zero-based keys to offset delta.

    k: (..., S, KV, D) — leading dims (layers/groups) fold into the kernel's
    batch axis; one launch regardless of layer count.
    """
    flat = k.reshape((-1,) + k.shape[-3:])
    d = jnp.broadcast_to(jnp.asarray(delta, jnp.int32).reshape(-1, 1),
                         (flat.shape[0], 1))
    out = rope_shift(flat, d, rotary_dim=rotary_dim, theta=theta,
                     interleaved=interleaved, interpret=interpret)
    return out.reshape(k.shape)


@functools.partial(jax.jit, static_argnames=(
    "rotary_dim", "theta", "interleaved", "interpret"))
def reencode_tokens_kv(k, deltas, rotary_dim: int, theta: float,
                       interleaved: bool = False, interpret: bool = INTERPRET):
    """Per-TOKEN-delta Eq.-3 re-rotation: token (b, t) shifts by its own
    offset — the PAGED assembly's rope as ONE kernel launch.

    k: (..., B, S, KV, D) — leading dims (layer groups) fold into the
    kernel's batch axis; deltas: (B, S) int32 per-token target offsets
    (shared across the folded leading dims).
    """
    B, S = k.shape[-4], k.shape[-3]
    flat = k.reshape((-1,) + k.shape[-4:])            # (M, B, S, KV, D)
    M = flat.shape[0]
    d = jnp.broadcast_to(jnp.asarray(deltas, jnp.int32), (B, S))
    d = jnp.broadcast_to(d[None], (M, B, S)).reshape(M * B, S)
    out = rope_shift_tokens(flat.reshape((M * B,) + k.shape[-3:]), d,
                            rotary_dim=rotary_dim, theta=theta,
                            interleaved=interleaved, interpret=interpret)
    return out.reshape(k.shape)


@functools.partial(jax.jit, static_argnames=(
    "rotary_dim", "theta", "interleaved", "interpret"))
def reencode_blocks_kv(k, deltas, rotary_dim: int, theta: float,
                       interleaved: bool = False, interpret: bool = INTERPRET):
    """Ragged-delta Eq.-3 re-rotation: block b shifts by its OWN offset.

    k: (nb, ..., S, KV, D) stacked per-block zero-based keys (inner leading
    dims — layers/groups — fold into the kernel's batch axis);
    deltas: (nb,) int32 per-block target offsets. ONE kernel launch for the
    whole fetched block set. Library surface: the serving assembly itself
    now runs the per-TOKEN form (``reencode_tokens_kv`` — every request
    assembles through the paged path, DESIGN.md §7); this per-BLOCK form
    remains for callers holding stacked equal-padded block sets.
    """
    nb = k.shape[0]
    flat = k.reshape((nb, -1) + k.shape[-3:])         # (nb, M, S, KV, D)
    M = flat.shape[1]
    d = jnp.repeat(jnp.asarray(deltas, jnp.int32).reshape(nb), M)[:, None]
    out = rope_shift(flat.reshape((nb * M,) + k.shape[-3:]), d,
                     rotary_dim=rotary_dim, theta=theta,
                     interleaved=interleaved, interpret=interpret)
    return out.reshape(k.shape)
