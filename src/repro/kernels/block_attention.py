"""Pallas TPU kernels: causal flash attention with GQA + the ragged
Block-attention prefill.

``flash_causal`` realises both halves of *uniform* Block-attention prefill
(the paper's Fig. 1 mask) via grid-level sparsity instead of in-kernel
masking waste:

  * within-block passes — blocks are folded into the batch dimension by the
    caller, so the KV grid only ever spans one block: cross-block tiles are
    never visited. FLOPs scale with Σ block_len² instead of S².
  * final-block global pass — the same kernel with ``q_offset = S - L``:
    the query block attends the whole sequence causally.

``flash_block_ragged`` is the serving hot path: ONE launch computes the
whole Block-attention mask for *variable-length* blocks. The cumulative
block boundaries arrive as a scalar-prefetched SMEM array — a **batched**
``(B, nb+1)`` boundary map: each of the ``N = B*KV`` grid rows reads ITS
row's boundaries (``row = n // kv_heads``), so a per-row ragged batch
(every row a different block-length signature) runs in one launch with
per-row tile-granular grid sparsity. A legacy ``(nb+1,)`` operand
broadcasts one layout to every row. Each grid step derives, from the
boundary scalars alone,

  * a per-tile liveness test (grid sparsity: a KV tile left of the query
    tile's lowest block start, or right of the causal frontier, is skipped
    with ``pl.when`` — the MXU does no work for it), and
  * the exact per-row attention window ``[lo(q), q]`` where ``lo(q)`` is the
    start of q's block, or 0 for final-block (and thus global) queries.

No ``S % num_blocks == 0`` restriction, no separate final-block launch.

Grid: (B*KV, num_q_tiles, num_kv_tiles); the KV dimension is the innermost
(sequential) axis — running max / denominator / accumulator live in VMEM
scratch across KV iterations (the canonical TPU flash-attention schedule).

BlockSpec tiling (VMEM working set, bf16 in / f32 acc):
  q tile (1, G, TQ, D) + acc (G, TQ, D) f32 + k/v tiles (TK, D)
  with TQ=256, TK=512, G<=8, D=128  ->  ~0.5 + 1.0 + 0.25 MB << 16 MB VMEM,
  and TQ/TK/D all multiples of the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_TQ = 256
DEFAULT_TK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, q_offset: int, kv_len: int,
                  tq: int, tk: int, softcap: float):
    """One (n, i, j) grid step: q tile i accumulates kv tile j."""
    i = pl.program_id(1)
    j = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal frontier: last query of this tile sits at global position
    # q_offset + (i+1)*tq - 1; kv tile j starts at j*tk.
    q_hi = q_offset + (i + 1) * tq - 1
    live = (j * tk <= q_hi) & (j * tk < kv_len)

    @pl.when(live)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale              # (G, TQ, D)
        k = k_ref[0].astype(jnp.float32)                      # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, TK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_offset + i * tq + jax.lax.broadcasted_iota(
            jnp.int32, (tq, tk), 0)
        kv_pos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = (kv_pos <= q_pos) & (kv_pos < kv_len)
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_ref[...]                                   # (G, TQ)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, D)
        m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_causal(
    q: jax.Array,            # (N, G, Sq, D)   N = batch * kv_heads
    k: jax.Array,            # (N, Skv, D)
    v: jax.Array,            # (N, Skv, D)
    *,
    scale: float,
    q_offset: int = 0,       # global position of q[.., 0, ..] on the kv axis
    kv_len: int = 0,         # valid kv length (0 -> Skv)
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    N, G, Sq, D = q.shape
    Skv = k.shape[1]
    kv_len = kv_len or Skv
    tq = min(tq, Sq)
    tk = min(tk, Skv)
    assert Sq % tq == 0 and Skv % tk == 0, (Sq, tq, Skv, tk)
    grid = (N, Sq // tq, Skv // tk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, q_offset=q_offset, kv_len=kv_len,
        tq=tq, tk=tk, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, tq, D), lambda n, i, j: (n, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, tk, D), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, tq, D), lambda n, i, j: (n, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, tq), jnp.float32),        # running max m
            pltpu.VMEM((G, tq), jnp.float32),        # denominator l
            pltpu.VMEM((G, tq, D), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Ragged Block-attention prefill: one launch, scalar-prefetched block map
# ---------------------------------------------------------------------------
def _ragged_kernel(starts_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, nb: int, tq: int, tk: int,
                   softcap: float, heads_per_row: int):
    """One (n, i, j) grid step of the ragged-block prefill.

    ``starts_ref`` (SMEM, scalar-prefetched): (B, nb + 1) cumulative block
    boundaries with ``starts[b, 0] == 0`` and ``starts[b, nb] == row b's
    valid kv length``. Grid row ``n`` (= batch*kv_heads) reads boundary row
    ``n // heads_per_row``. Row q attends [lo(q), q] with lo(q) = start of
    q's block, or 0 for rows in the final block (the paper's global query
    block).
    """
    n = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    b = n // heads_per_row
    kv_len = starts_ref[b, nb]
    final_start = starts_ref[b, nb - 1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- tile liveness from the boundary scalars alone -------------------
    # lo of the tile's FIRST row: the largest block start <= i*tq.  lo(q) is
    # non-decreasing in q except in the final block where it drops to 0, so
    # the tile-wide minimum is 0 whenever the tile overlaps the final block.
    lo_first = jnp.int32(0)
    for blk in range(1, nb):
        sb = starts_ref[b, blk]
        lo_first = jnp.where(i * tq >= sb, sb, lo_first)
    q_hi = (i + 1) * tq - 1                       # causal frontier of the tile
    tile_lo = jnp.where(q_hi >= final_start, 0, lo_first)
    live = (j * tk <= jnp.minimum(q_hi, kv_len - 1)) & \
        ((j + 1) * tk > tile_lo) & (i * tq < kv_len)

    @pl.when(live)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale              # (G, TQ, D)
        k = k_ref[0].astype(jnp.float32)                      # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, TK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        # per-row window lower bound lo(q): VPU work on a (TQ, 1) column
        q_pos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)
        lo = jnp.zeros((tq, 1), jnp.int32)
        for blk in range(1, nb):
            sb = starts_ref[b, blk]
            lo = jnp.where(q_pos >= sb, sb, lo)
        lo = jnp.where(q_pos >= final_start, 0, lo)           # global final blk
        kv_pos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = (kv_pos <= q_pos) & (kv_pos >= lo) & (kv_pos < kv_len)
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_ref[...]                                   # (G, TQ)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, D)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def _ragged_kernel_sel(starts_ref, keep_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, scale: float, nb: int,
                       tq: int, tk: int, softcap: float, heads_per_row: int):
    """Ragged-block prefill with top-k block selection on the FINAL-pass
    rows (DESIGN.md §10): non-final rows attend their own block exactly as
    in ``_ragged_kernel``; rows in the final (global) block additionally
    mask out kv positions in deselected non-final blocks. ``keep_ref``
    (SMEM) is (B, nb) 0/1 over blocks — its final column is ignored (the
    final block is always kept). Tiles made of final rows only skip KV
    tiles overlapping no kept range (grid-level selection sparsity)."""
    n = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    b = n // heads_per_row
    kv_len = starts_ref[b, nb]
    final_start = starts_ref[b, nb - 1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo_first = jnp.int32(0)
    for blk in range(1, nb):
        sb = starts_ref[b, blk]
        lo_first = jnp.where(i * tq >= sb, sb, lo_first)
    q_hi = (i + 1) * tq - 1
    tile_lo = jnp.where(q_hi >= final_start, 0, lo_first)
    live = (j * tk <= jnp.minimum(q_hi, kv_len - 1)) & \
        ((j + 1) * tk > tile_lo) & (i * tq < kv_len)
    # selection refinement: a tile made of final rows ONLY is dead unless
    # its kv tile overlaps the final region or a kept non-final block
    sel_live = (j + 1) * tk > final_start
    for blk in range(nb - 1):
        sel_live |= ((keep_ref[b, blk] > 0)
                     & ((j + 1) * tk > starts_ref[b, blk])
                     & (j * tk < starts_ref[b, blk + 1]))
    live &= jnp.where(i * tq >= final_start, sel_live, True)

    @pl.when(live)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale              # (G, TQ, D)
        k = k_ref[0].astype(jnp.float32)                      # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, TK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)
        lo = jnp.zeros((tq, 1), jnp.int32)
        for blk in range(1, nb):
            sb = starts_ref[b, blk]
            lo = jnp.where(q_pos >= sb, sb, lo)
        lo = jnp.where(q_pos >= final_start, 0, lo)           # global final blk
        kv_pos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = (kv_pos <= q_pos) & (kv_pos >= lo) & (kv_pos < kv_len)
        # final-pass rows only see kept blocks (+ the final region itself)
        keep_kv = kv_pos >= final_start
        for blk in range(nb - 1):
            keep_kv |= ((keep_ref[b, blk] > 0)
                        & (kv_pos >= starts_ref[b, blk])
                        & (kv_pos < starts_ref[b, blk + 1]))
        mask &= (q_pos < final_start) | keep_kv
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_ref[...]                                   # (G, TQ)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, D)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_block_ragged(
    q: jax.Array,            # (N, G, Sp, D)   N = batch * kv_heads
    k: jax.Array,            # (N, Sp, D)      Sp padded to tile multiples
    v: jax.Array,            # (N, Sp, D)
    starts: jax.Array,       # (B, nb + 1) int32 PER-ROW cumulative block
                             # boundaries (B must divide N; row n reads
                             # starts[n // (N//B)]); starts[b, nb] = row b's
                             # valid length (<= Sp). Legacy (nb + 1,) form
                             # broadcasts one layout to every row.
    *,
    scale: float,
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    softcap: float = 0.0,
    interpret: bool = True,
    sel_keep: jax.Array = None,   # (B, nb) 0/1 block keep flags; final col
                                  # ignored (final block always kept). None
                                  # -> the original unselected program.
) -> jax.Array:
    """Whole (per-row ragged) Block-attention prefill in ONE kernel launch.

    Rows beyond ``starts[b, -1]`` (q padding) hold UNSPECIFIED values —
    zeros when their whole tile is dead, unmasked attention over the real
    keys when the tile straddles the valid boundary (their ``lo`` falls to
    0 like final-block rows). Callers MUST slice/mask the output back to
    the valid length. Pad *keys* are always masked out via the boundary
    scalars.

    With ``sel_keep``, final-block rows attend only kept blocks (plus the
    final region); non-final rows are untouched (DESIGN.md §10).
    """
    N, G, Sq, D = q.shape
    Skv = k.shape[1]
    if starts.ndim == 1:
        starts = starts[None]
    B, nb1 = starts.shape
    nb = nb1 - 1
    assert N % B == 0, (N, B)
    heads_per_row = N // B
    tq = min(tq, Sq)
    tk = min(tk, Skv)
    assert Sq % tq == 0 and Skv % tk == 0, (Sq, tq, Skv, tk)
    grid = (N, Sq // tq, Skv // tk)

    if sel_keep is not None:
        sel_keep = jnp.asarray(sel_keep, jnp.int32)
        if sel_keep.ndim == 1:
            sel_keep = sel_keep[None]
        assert sel_keep.shape == (B, nb), (sel_keep.shape, B, nb)
        kernel = functools.partial(_ragged_kernel_sel, scale=scale, nb=nb,
                                   tq=tq, tk=tk, softcap=softcap,
                                   heads_per_row=heads_per_row)
        n_scalar = 2
        operands = (starts, sel_keep)
    else:
        kernel = functools.partial(_ragged_kernel, scale=scale, nb=nb,
                                   tq=tq, tk=tk, softcap=softcap,
                                   heads_per_row=heads_per_row)
        n_scalar = 1
        operands = (starts,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_scalar,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, tq, D), lambda n, i, j, *refs: (n, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda n, i, j, *refs: (n, j, 0)),
            pl.BlockSpec((1, tk, D), lambda n, i, j, *refs: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, tq, D),
                               lambda n, i, j, *refs: (n, 0, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, tq), jnp.float32),        # running max m
            pltpu.VMEM((G, tq), jnp.float32),        # denominator l
            pltpu.VMEM((G, tq, D), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, G, Sq, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands, q, k, v)
