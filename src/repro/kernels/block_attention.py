"""Pallas TPU kernel: causal flash attention with GQA + query offset.

This single kernel realises both halves of Block-attention prefill
(the paper's Fig. 1 mask) via *grid-level sparsity* instead of in-kernel
masking waste:

  * within-block passes — blocks are folded into the batch dimension by the
    caller (``ops.block_attention_prefill``), so the KV grid only ever spans
    one block: cross-block tiles are never visited. FLOPs scale with
    Σ block_len² instead of S².
  * final-block global pass — the same kernel with ``q_offset = S - L``:
    the query block attends the whole sequence causally.

Grid: (B*KV, num_q_tiles, num_kv_tiles); the KV dimension is the innermost
(sequential) axis — running max / denominator / accumulator live in VMEM
scratch across KV iterations (the canonical TPU flash-attention schedule).
Fully-masked KV tiles (beyond the causal frontier) are skipped with
``pl.when``: the MXU does no work for them.

BlockSpec tiling (VMEM working set, bf16 in / f32 acc):
  q tile (1, G, TQ, D) + acc (G, TQ, D) f32 + k/v tiles (TK, D)
  with TQ=256, TK=512, G<=8, D=128  ->  ~0.5 + 1.0 + 0.25 MB << 16 MB VMEM,
  and TQ/TK/D all multiples of the 128-lane MXU tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

DEFAULT_TQ = 256
DEFAULT_TK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, q_offset: int, kv_len: int,
                  tq: int, tk: int, softcap: float):
    """One (n, i, j) grid step: q tile i accumulates kv tile j."""
    i = pl.program_id(1)
    j = pl.program_id(2)
    nkv = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal frontier: last query of this tile sits at global position
    # q_offset + (i+1)*tq - 1; kv tile j starts at j*tk.
    q_hi = q_offset + (i + 1) * tq - 1
    live = (j * tk <= q_hi) & (j * tk < kv_len)

    @pl.when(live)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale              # (G, TQ, D)
        k = k_ref[0].astype(jnp.float32)                      # (TK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, TK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_offset + i * tq + jax.lax.broadcasted_iota(
            jnp.int32, (tq, tk), 0)
        kv_pos = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = (kv_pos <= q_pos) & (kv_pos < kv_len)
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_ref[...]                                   # (G, TQ)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (G, TQ, D)
        m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_causal(
    q: jax.Array,            # (N, G, Sq, D)   N = batch * kv_heads
    k: jax.Array,            # (N, Skv, D)
    v: jax.Array,            # (N, Skv, D)
    *,
    scale: float,
    q_offset: int = 0,       # global position of q[.., 0, ..] on the kv axis
    kv_len: int = 0,         # valid kv length (0 -> Skv)
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    N, G, Sq, D = q.shape
    Skv = k.shape[1]
    kv_len = kv_len or Skv
    tq = min(tq, Sq)
    tk = min(tk, Skv)
    assert Sq % tq == 0 and Skv % tk == 0, (Sq, tq, Skv, tk)
    grid = (N, Sq // tq, Skv // tk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, q_offset=q_offset, kv_len=kv_len,
        tq=tq, tk=tk, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, tq, D), lambda n, i, j: (n, 0, i, 0)),
            pl.BlockSpec((1, tk, D), lambda n, i, j: (n, j, 0)),
            pl.BlockSpec((1, tk, D), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, tq, D), lambda n, i, j: (n, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, tq), jnp.float32),        # running max m
            pltpu.VMEM((G, tq), jnp.float32),        # denominator l
            pltpu.VMEM((G, tq, D), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
